"""Binary-sketch pre-filter tier (DESIGN.md §Binary sketch tier).

Oracle discipline mirrors the quantized tiers: the packed representation
round-trips exactly, the Pallas pre-filter is bit-identical to the
natural-order NumPy/JAX Hamming oracle across bank liveness patterns, the
sketch table stays byte-exact through upsert and checkpoint, and the full
sketch -> int4/int8 -> rescore ladder is bit-identical to the unfiltered
search at a covering ``sketch_factor``.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import clustering, lider, update
from repro.core.utils import recall_at_k
from repro.kernels import ref
from repro.kernels.fused_verify import sketch_prefilter
from repro.kernels.quant import (
    SKETCH_WORD_BITS,
    sketch_rows,
    sketch_width,
    unpack_sketch,
)
from repro.training import checkpoint

CFG = lider.LiderConfig(
    n_clusters=32, n_probe=8, n_arrays=4, n_leaves=4, kmeans_iters=10
)


def _cfg(storage_dtype, **kw):
    return dataclasses.replace(CFG, storage_dtype=storage_dtype, **kw)


# ---------------------------------------------------------------------------
# Packing: round-trip + padding conventions
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("d", [1, 31, 32, 33, 64, 96, 100])
def test_sketch_pack_unpack_roundtrip(d):
    """Deterministic round-trip at the width edge cases (the hypothesis
    sweep below explores the space when the optional dep is present)."""
    rng = np.random.default_rng(0)
    x = rng.normal(size=(17, d)).astype(np.float32)
    x[3] = 0.0  # all-zero (padded-slot) row
    words = sketch_rows(jnp.asarray(x))
    assert words.shape == (17, sketch_width(d))
    assert words.dtype == jnp.uint32
    np.testing.assert_array_equal(np.asarray(unpack_sketch(words, d)), x > 0)
    # Zero rows pack to zero words; bits past d stay zero on every row (so
    # they XOR away against the identically-padded query sketch).
    np.testing.assert_array_equal(np.asarray(words[3]), 0)
    if d % SKETCH_WORD_BITS:
        full = unpack_sketch(words, sketch_width(d) * SKETCH_WORD_BITS)
        assert not np.asarray(full)[:, d:].any()


def test_sketch_pack_unpack_roundtrip_hypothesis():
    pytest.importorskip("hypothesis")  # optional dep: deterministic test above
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=60, deadline=None)
    @given(st.integers(1, 130), st.integers(0, 2**31 - 1))
    def inner(d, seed):
        rng = np.random.default_rng(seed)
        # signs including exact zeros (strict > 0 predicate)
        x = rng.choice([-1.0, 0.0, 1.0], size=(4, d)).astype(np.float32)
        words = sketch_rows(jnp.asarray(x))
        np.testing.assert_array_equal(
            np.asarray(unpack_sketch(words, d)), x > 0
        )

    inner()


def test_sketch_hamming_scores_are_exact():
    """ref scores == the independent NumPy bit-count Hamming, negated."""
    rng = np.random.default_rng(3)
    n, d, b, c = 40, 50, 4, 12
    embs = rng.normal(size=(n, d)).astype(np.float32)
    q = rng.normal(size=(b, d)).astype(np.float32)
    ids = rng.integers(0, n, size=(b, c)).astype(np.int32)
    table = sketch_rows(jnp.asarray(embs))
    got_ids, got_sc = ref.sketch_topk_ref(
        table, jnp.asarray(ids), jnp.asarray(q), k=c
    )
    tb, qb = embs > 0, q > 0  # unpacked bit matrices
    for i in range(b):
        for j in range(c):
            rid = int(np.asarray(got_ids)[i, j])
            if rid < 0:
                continue
            ham = int(np.sum(tb[rid] != qb[i]))
            assert float(np.asarray(got_sc)[i, j]) == -float(ham)


# ---------------------------------------------------------------------------
# Kernel vs oracle parity across bank liveness patterns
# ---------------------------------------------------------------------------


def _mask(ids, pattern, block_c):
    if pattern == "all_live":
        return ids
    if pattern == "tombstoned":  # scattered dead candidates
        return ids.at[:, ::3].set(-1)
    if pattern == "dead_block":  # one fully-dead candidate block per row
        return ids.at[:, block_c : 2 * block_c].set(-1)
    if pattern == "all_pruned_row":  # row 0 entirely dead
        return ids.at[0, :].set(-1)
    raise ValueError(pattern)


@pytest.mark.parametrize(
    "pattern", ["all_live", "tombstoned", "dead_block", "all_pruned_row"]
)
def test_sketch_kernel_matches_oracle(pattern):
    block_c = 8
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(7), 3)
    embs = jax.random.normal(k1, (64, 48))
    ids = jax.random.randint(k2, (3, 4 * block_c), 0, 64)
    q = jax.random.normal(k3, (3, 48))
    ids = _mask(ids, pattern, block_c)
    table = sketch_rows(embs)
    gi, gs = sketch_prefilter(table, ids, q, k=6, block_c=block_c, interpret=True)
    wi, ws = ref.sketch_topk_ref(table, ids, q, k=6)
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
    np.testing.assert_array_equal(np.asarray(gs), np.asarray(ws))
    if pattern == "all_pruned_row":
        assert (np.asarray(gi)[0] == -1).all()
        assert np.isneginf(np.asarray(gs)[0]).all()


def test_sketch_out_ids_suppression_matches_oracle():
    """Tombstoned candidates (``out_ids`` < 0) are suppressed identically by
    kernel and oracle — the same contract as ``verify_topk_op``."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(9), 3)
    embs = jax.random.normal(k1, (32, 32))
    rows = jax.random.randint(k2, (2, 16), 0, 32)
    q = jax.random.normal(k3, (2, 32))
    out_ids = rows.at[:, 1::2].set(-1)  # every other candidate tombstoned
    table = sketch_rows(embs)
    gi, gs = sketch_prefilter(
        table, rows, q, k=8, out_ids=out_ids, block_c=8, interpret=True
    )
    wi, ws = ref.sketch_topk_ref(table, rows, q, k=8, out_ids=out_ids)
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
    np.testing.assert_array_equal(np.asarray(gs), np.asarray(ws))
    live = set(np.asarray(out_ids)[np.asarray(out_ids) >= 0].ravel().tolist())
    got = np.asarray(gi)
    assert set(got[got >= 0].ravel().tolist()) <= live


# ---------------------------------------------------------------------------
# Bank lifecycle: upsert / checkpoint keep sketches in lockstep with codes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sd", ["int8", "int4"])
def test_sketch_upsert_matches_full_rebuild(corpus, sd):
    """build(80%) -> upsert(20%) produces a byte-identical sketch table to
    build(100%) under frozen layer-1 (sketching is row-local, like the
    quantizers), and the table always equals re-sketching the raw rows."""
    x, _, _ = corpus
    n80 = int(x.shape[0] * 0.8)
    km = clustering.kmeans(jax.random.PRNGKey(2), x[:n80], CFG.n_clusters, iters=10)
    assignment, _ = clustering.assign_chunked(x, km.centroids)
    max_size = int(jnp.bincount(assignment, length=CFG.n_clusters).max())
    cfg = _cfg(
        sd, capacity=lider.padded_capacity(max_size, None, CFG.pad_multiple)
    )
    full = lider.build_lider(jax.random.PRNGKey(2), x, cfg, centroids=km.centroids)
    base = lider.build_lider(
        jax.random.PRNGKey(2), x[:n80], cfg, centroids=km.centroids
    )
    up, _ = update.upsert(base, x[n80:])
    assert up.bank.sketches is not None
    np.testing.assert_array_equal(
        np.asarray(up.bank.sketches), np.asarray(full.bank.sketches)
    )
    raw = (
        up.bank.rescore_embs
        if up.bank.rescore_embs is not None
        else up.bank.store.rescore
    )
    np.testing.assert_array_equal(
        np.asarray(up.bank.sketches), np.asarray(sketch_rows(jnp.asarray(raw)))
    )


def test_sketch_compaction_keeps_lockstep(corpus):
    """Compaction (threshold-0 delete) permutes sketches with the codes:
    the table still equals re-sketching the compacted raw rows."""
    x, q, _ = corpus
    p = lider.build_lider(jax.random.PRNGKey(2), x, _cfg("int8"))
    before = lider.search_lider(p, q, k=10, n_probe=8, r0=8)
    dead = np.unique(np.asarray(before.ids)[:, :3].ravel())
    dead = jnp.asarray(dead[dead >= 0][:50], jnp.int32)
    p2, stats = update.delete(p, dead, refit_threshold=0.0)
    assert stats.n_refit > 0
    np.testing.assert_array_equal(
        np.asarray(p2.bank.sketches),
        np.asarray(sketch_rows(jnp.asarray(p2.bank.rescore_embs))),
    )


def test_checkpoint_roundtrip_preserves_sketches(tmp_path, corpus):
    x, _, _ = corpus
    p = lider.build_lider(jax.random.PRNGKey(0), x, _cfg("int4"))
    checkpoint.save_index(str(tmp_path), p)
    p2 = checkpoint.load_index(str(tmp_path))
    np.testing.assert_array_equal(
        np.asarray(p.bank.sketches), np.asarray(p2.bank.sketches)
    )


def test_checkpoint_presketch_fallback_recomputes_byte_exact(tmp_path, corpus):
    """Loading a pre-sketch-era checkpoint (no ``bank__sketches.npy``)
    recomputes the table from the rescore rows — byte-exact, because the
    sketch is a pure row-local function of the raw rows."""
    x, q, _ = corpus
    p = lider.build_lider(jax.random.PRNGKey(0), x, _cfg("int8"))
    checkpoint.save_index(str(tmp_path), p)
    os.remove(os.path.join(str(tmp_path), "index", "bank__sketches.npy"))
    p2 = checkpoint.load_index(str(tmp_path))
    assert p2.bank.sketches is not None
    np.testing.assert_array_equal(
        np.asarray(p.bank.sketches), np.asarray(p2.bank.sketches)
    )
    a = lider.search_lider(p, q, k=10, n_probe=8, r0=8, sketch_factor=4)
    b = lider.search_lider(p2, q, k=10, n_probe=8, r0=8, sketch_factor=4)
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))


# ---------------------------------------------------------------------------
# End-to-end: covering factor is bit-identical; small factors hold recall
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sd", ["int8", "int4"])
def test_search_covering_sketch_factor_bit_identical(corpus, sd):
    """A ``sketch_factor`` covering every distinct candidate makes the
    pre-filter a no-op: ids AND scores match the unfiltered search exactly,
    on the per-query and the cluster-major spellings."""
    x, q, _ = corpus
    p = lider.build_lider(jax.random.PRNGKey(0), x, _cfg(sd))
    base = lider.search_lider(p, q, k=10, n_probe=8, r0=8)
    cov = lider.search_lider(p, q, k=10, n_probe=8, r0=8, sketch_factor=64)
    np.testing.assert_array_equal(np.asarray(base.ids), np.asarray(cov.ids))
    np.testing.assert_array_equal(
        np.asarray(base.scores), np.asarray(cov.scores)
    )
    cm = lider.search_lider(p, q, k=10, n_probe=8, r0=8, block_q=4)
    cm_cov = lider.search_lider(
        p, q, k=10, n_probe=8, r0=8, block_q=4, sketch_factor=64
    )
    np.testing.assert_array_equal(np.asarray(cm.ids), np.asarray(cm_cov.ids))
    np.testing.assert_array_equal(
        np.asarray(cm.scores), np.asarray(cm_cov.scores)
    )


def test_sketch_float_bank_rejects_nothing_silently(corpus):
    """A float bank has no sketches; passing sketch_factor is a no-op (the
    pre-filter gates on ``bank.sketches is not None``)."""
    x, q, _ = corpus
    p = lider.build_lider(jax.random.PRNGKey(0), x, _cfg("float32"))
    assert p.bank.sketches is None
    a = lider.search_lider(p, q, k=10, n_probe=8, r0=8)
    b = lider.search_lider(p, q, k=10, n_probe=8, r0=8, sketch_factor=4)
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))


def test_sketch_recall_floor(corpus):
    """Serving-grade operating point: sketch + int4 + exact rescore recalls
    within 0.02 of the plain int4 + rescore pass (the BENCH_verify gate)."""
    x, q, gt = corpus
    p = lider.build_lider(jax.random.PRNGKey(0), x, _cfg("int4"))
    plain = lider.search_lider(p, q, k=10, n_probe=8, r0=8)
    sk = lider.search_lider(p, q, k=10, n_probe=8, r0=8, sketch_factor=4)
    r_plain = float(recall_at_k(plain.ids, gt))
    r_sk = float(recall_at_k(sk.ids, gt))
    assert r_sk >= r_plain - 0.02, (r_sk, r_plain)

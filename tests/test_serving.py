"""Serving engine: batching, padding, result routing, AQT accounting."""
import time

import jax
import numpy as np
import pytest

from repro.core import lider
from repro.core.baselines import flat_search
from repro.core.core_model import TopK
from repro.serving import RetrievalEngine, make_backend


def test_engine_routes_results_correctly(corpus):
    x, q, _ = corpus
    search = make_backend("flat", None, x)
    engine = RetrievalEngine(search, batch_size=16, k=5, dim=x.shape[1])
    engine.warmup()
    qs = np.asarray(q)[:40]  # not a multiple of batch size -> padding path
    rids = [engine.submit(v) for v in qs]
    engine.drain()
    gt = flat_search(x, q[:40], k=5)
    for i, rid in enumerate(rids):
        ids, scores = engine.result(rid)
        np.testing.assert_array_equal(ids, np.asarray(gt.ids)[i])
    assert engine.stats.n_queries == 40
    assert engine.stats.n_batches == 3  # ceil(40/16)
    assert engine.stats.aqt > 0
    # partial-batch padding accounting: 3 batches x 16 slots, 40 real queries
    assert engine.stats.n_padded == 8
    assert engine.stats.padding_fraction == pytest.approx(8 / 48)


def test_engine_full_batches_have_zero_padding(corpus):
    x, q, _ = corpus
    search = make_backend("flat", None, x)
    engine = RetrievalEngine(search, batch_size=16, k=5, dim=x.shape[1])
    for v in np.asarray(q)[:32]:
        engine.submit(v)
    engine.drain()
    assert engine.stats.n_padded == 0
    assert engine.stats.padding_fraction == 0.0


def test_engine_lider_backend(corpus):
    x, q, gt = corpus
    cfg = lider.LiderConfig(n_clusters=32, n_probe=8, n_arrays=4, n_leaves=4, kmeans_iters=8)
    index = lider.build_lider(jax.random.PRNGKey(0), x, cfg)
    search = make_backend("lider", index, n_probe=8, r0=8, use_fused=False)
    engine = RetrievalEngine(search, batch_size=32, k=10, dim=x.shape[1])
    rids = [engine.submit(v) for v in np.asarray(q)[:32]]
    engine.drain()
    hits = 0
    for i, rid in enumerate(rids):
        ids, _ = engine.result(rid)
        hits += len(set(ids.tolist()) & set(np.asarray(gt)[i].tolist()))
    assert hits / (32 * 10) > 0.8
    # no pruning configured -> no probe stats accumulated
    assert engine.stats.n_probes_total == 0
    assert len(engine.stats.batch_pruned_fraction) == 0


def test_engine_lider_backend_reports_pruned_probes(corpus):
    x, q, _ = corpus
    cfg = lider.LiderConfig(n_clusters=32, n_probe=8, n_arrays=4, n_leaves=4, kmeans_iters=8)
    index = lider.build_lider(jax.random.PRNGKey(0), x, cfg)
    search = make_backend(
        "lider", index, n_probe=8, r0=8, use_fused=False, prune_margin=0.1
    )
    engine = RetrievalEngine(search, batch_size=16, k=10, dim=x.shape[1])
    rids = [engine.submit(v) for v in np.asarray(q)[:40]]  # padded last batch
    engine.drain()
    s = engine.stats
    # only real queries count: 40 x 8 probes, not 48 x 8
    assert s.n_probes_total == 40 * 8
    assert 0 < s.n_probes_pruned < s.n_probes_total
    assert len(s.batch_pruned_fraction) == s.n_batches == 3
    assert s.pruned_probe_fraction == pytest.approx(
        s.n_probes_pruned / s.n_probes_total
    )
    for rid in rids:
        assert engine.result(rid) is not None


# ---------------------------------------------------------------------------
# Regression: results-map memory leak (results grew without bound)
# ---------------------------------------------------------------------------


def test_results_map_does_not_grow_across_drains(corpus):
    """A long-running engine whose clients collect answers must hold zero
    retained results between rounds — result() pops by default."""
    x, q, _ = corpus
    search = make_backend("flat", None, x)
    engine = RetrievalEngine(search, batch_size=16, k=5, dim=x.shape[1])
    engine.warmup()
    qs = np.asarray(q)[:16]
    sizes = []
    for _ in range(4):
        rids = [engine.submit(v) for v in qs]
        engine.drain()
        for rid in rids:
            assert engine.result(rid) is not None
        sizes.append(len(engine.results))
    assert sizes == [0, 0, 0, 0]
    # popped once -> gone (no second copy retained anywhere)
    assert engine.result(rids[0]) is None


def test_result_keep_leaves_entry_in_map(corpus):
    x, q, _ = corpus
    search = make_backend("flat", None, x)
    engine = RetrievalEngine(search, batch_size=8, k=5, dim=x.shape[1])
    rid = engine.submit(np.asarray(q)[0])
    engine.drain()
    assert engine.result(rid, keep=True) is not None
    assert len(engine.results) == 1  # still there
    assert engine.result(rid) is not None  # pop
    assert len(engine.results) == 0


def test_results_map_bounded_when_never_collected(corpus):
    """Clients that never call result() must not leak: the map is bounded
    and evicts oldest-first."""
    x, q, _ = corpus
    search = make_backend("flat", None, x)
    engine = RetrievalEngine(
        search, batch_size=16, k=5, dim=x.shape[1], max_results=32
    )
    rids = []
    for _ in range(4):  # 64 answered, bound is 32
        rids += [engine.submit(v) for v in np.asarray(q)[:16]]
        engine.drain()
    assert len(engine.results) == 32
    assert engine.stats.n_results_evicted == 32
    from repro.serving import EVICTED

    for rid in rids[:32]:  # oldest evicted -> falsy sentinel, not None
        assert engine.result(rid) is EVICTED
        assert not engine.result(rid)
    for rid in rids[32:]:  # newest retained
        assert engine.result(rid) is not None


def test_max_results_must_fit_a_batch(corpus):
    x, _, _ = corpus
    search = make_backend("flat", None, x)
    with pytest.raises(ValueError):
        RetrievalEngine(search, batch_size=16, k=5, dim=x.shape[1], max_results=8)


# ---------------------------------------------------------------------------
# Regression: AQT window must cover device time only (no D2H conversion)
# ---------------------------------------------------------------------------


class _SlowHostArray:
    """Device-complete result whose host conversion is expensive — models a
    large (B, k) transfer. block_until_ready is instant; np.asarray sleeps."""

    def __init__(self, arr, delay_s):
        self._arr = arr
        self._delay_s = delay_s

    def block_until_ready(self):
        return self

    def __array__(self, dtype=None, copy=None):
        time.sleep(self._delay_s)
        return self._arr


def test_aqt_window_excludes_host_copies():
    b, k, dim, delay = 4, 3, 8, 0.15

    def search(q, kk):
        ids = np.tile(np.arange(k, dtype=np.int32), (b, 1))
        scores = np.zeros((b, k), np.float32)
        return TopK(
            ids=_SlowHostArray(ids, delay), scores=_SlowHostArray(scores, delay)
        )

    engine = RetrievalEngine(search, batch_size=b, k=k, dim=dim)
    rids = [engine.submit(np.zeros(dim, np.float32)) for _ in range(b)]
    t0 = time.perf_counter()
    engine.drain()
    wall = time.perf_counter() - t0
    assert wall >= 2 * delay  # both conversions really happened...
    assert engine.stats.total_time_s < delay  # ...outside the AQT window
    ids, scores = engine.result(rids[0])
    np.testing.assert_array_equal(ids, np.arange(k, dtype=np.int32))

"""Serving engine: batching, padding, result routing, AQT accounting."""
import jax
import numpy as np
import pytest

from repro.core import lider
from repro.core.baselines import flat_search
from repro.serving import RetrievalEngine, make_backend


def test_engine_routes_results_correctly(corpus):
    x, q, _ = corpus
    search = make_backend("flat", None, x)
    engine = RetrievalEngine(search, batch_size=16, k=5, dim=x.shape[1])
    engine.warmup()
    qs = np.asarray(q)[:40]  # not a multiple of batch size -> padding path
    rids = [engine.submit(v) for v in qs]
    engine.drain()
    gt = flat_search(x, q[:40], k=5)
    for i, rid in enumerate(rids):
        ids, scores = engine.result(rid)
        np.testing.assert_array_equal(ids, np.asarray(gt.ids)[i])
    assert engine.stats.n_queries == 40
    assert engine.stats.n_batches == 3  # ceil(40/16)
    assert engine.stats.aqt > 0
    # partial-batch padding accounting: 3 batches x 16 slots, 40 real queries
    assert engine.stats.n_padded == 8
    assert engine.stats.padding_fraction == pytest.approx(8 / 48)


def test_engine_full_batches_have_zero_padding(corpus):
    x, q, _ = corpus
    search = make_backend("flat", None, x)
    engine = RetrievalEngine(search, batch_size=16, k=5, dim=x.shape[1])
    for v in np.asarray(q)[:32]:
        engine.submit(v)
    engine.drain()
    assert engine.stats.n_padded == 0
    assert engine.stats.padding_fraction == 0.0


def test_engine_lider_backend(corpus):
    x, q, gt = corpus
    cfg = lider.LiderConfig(n_clusters=32, n_probe=8, n_arrays=4, n_leaves=4, kmeans_iters=8)
    index = lider.build_lider(jax.random.PRNGKey(0), x, cfg)
    search = make_backend("lider", index, n_probe=8, r0=8, use_fused=False)
    engine = RetrievalEngine(search, batch_size=32, k=10, dim=x.shape[1])
    rids = [engine.submit(v) for v in np.asarray(q)[:32]]
    engine.drain()
    hits = 0
    for i, rid in enumerate(rids):
        ids, _ = engine.result(rid)
        hits += len(set(ids.tolist()) & set(np.asarray(gt)[i].tolist()))
    assert hits / (32 * 10) > 0.8

"""RMI + key re-scaling: fit quality, masked fits, and the paper's Table-4
claim that re-scaling removes out-of-range predictions."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: skip cleanly when absent
from hypothesis import given, settings, strategies as st

from repro.core import lsh, rescale, rmi


def _sorted_keys(seed, n, m=24):
    rng = np.random.default_rng(seed)
    return jnp.asarray(np.sort(rng.integers(0, 2**m, size=n)).astype(np.uint32))


def test_rescale_range_and_monotonicity():
    keys = _sorted_keys(0, 500)
    p = rescale.fit_rescale(keys)
    scaled = rescale.rescale(p, keys)
    assert float(scaled[0]) == 0.0
    assert abs(float(scaled[-1]) - 499.0) < 1e-3
    assert bool(jnp.all(jnp.diff(scaled) >= 0))
    # out-of-domain queries clip into range
    q = rescale.rescale(p, jnp.asarray([0, 2**31 - 1], jnp.uint32))
    assert float(q.min()) >= 0.0 and float(q.max()) <= 499.0


@given(st.integers(0, 1000), st.integers(50, 400), st.integers(2, 16))
@settings(max_examples=25, deadline=None)
def test_rmi_fit_accuracy_on_uniformish_keys(seed, n, leaves):
    keys = _sorted_keys(seed, n)
    p = rescale.fit_rescale(keys)
    scaled = rescale.rescale(p, keys)
    params = rmi.fit_rmi(scaled, jnp.ones_like(scaled), n_leaves=leaves)
    pred = rmi.predict(params, scaled)
    err = np.abs(np.asarray(pred) - np.arange(n))
    # uniform random ints are near-linear after min-max rescale
    assert err.mean() < n * 0.15
    assert bool(jnp.all(pred >= 0)) and bool(jnp.all(pred <= n - 1))


def test_rmi_masked_fit_matches_unpadded():
    keys = _sorted_keys(1, 200)
    padded = jnp.concatenate(
        [keys, jnp.full((56,), lsh.UINT32_PAD, jnp.uint32)]
    )
    w = jnp.concatenate([jnp.ones((200,)), jnp.zeros((56,))])
    p_pad = rescale.fit_rescale(padded, w > 0)
    p_ref = rescale.fit_rescale(keys)
    assert int(p_pad.key_min) == int(p_ref.key_min)
    assert int(p_pad.key_max) == int(p_ref.key_max)
    assert float(p_pad.length) == 200.0
    scaled_pad = rescale.rescale(p_pad, padded)
    params_pad = rmi.fit_rmi(scaled_pad, w, n_leaves=4)
    scaled = rescale.rescale(p_ref, keys)
    params_ref = rmi.fit_rmi(scaled, jnp.ones_like(scaled), n_leaves=4)
    np.testing.assert_allclose(
        np.asarray(params_pad.leaf_w), np.asarray(params_ref.leaf_w), rtol=1e-4
    )


def test_duplicate_keys_bounded_local_error():
    """Paper Sec 5.1: duplicate keys map to adjacent positions; the error is
    bounded by the duplicate run length."""
    base = np.sort(np.random.default_rng(2).integers(0, 2**20, 100))
    keys = jnp.asarray(np.repeat(base, 3).astype(np.uint32))  # runs of 3
    p = rescale.fit_rescale(keys)
    scaled = rescale.rescale(p, keys)
    params = rmi.fit_rmi(scaled, jnp.ones_like(scaled), n_leaves=8)
    pred = rmi.predict(params, scaled)
    err = np.abs(np.asarray(pred) - np.arange(300))
    assert err.max() < 60  # bounded, not exploding


def test_rescaling_removes_out_of_range_predictions():
    """Table 4 reproduction in miniature: fitting on raw (huge) integer keys
    yields mostly out-of-range predictions; re-scaled keys do not."""
    keys = _sorted_keys(3, 1000, m=30)
    n = keys.shape[0]
    y_hi = float(n - 1)

    # raw: keys as floats, no rescale
    raw = keys.astype(jnp.float32)
    params_raw = rmi.fit_rmi(raw / 1.0, jnp.ones_like(raw), n_leaves=5)
    # simulate the no-rescale pipeline: length is still n but inputs huge
    pred_raw = rmi.predict_raw(params_raw, raw)
    oor_raw = int(jnp.sum((pred_raw <= 0) | (pred_raw >= y_hi)))

    p = rescale.fit_rescale(keys)
    scaled = rescale.rescale(p, keys)
    params = rmi.fit_rmi(scaled, jnp.ones_like(scaled), n_leaves=5)
    pred = rmi.predict_raw(params, scaled)
    oor = int(jnp.sum((pred <= 0) | (pred >= y_hi)))
    # note: fit_rmi itself centers, so raw OOR mainly reflects fp32 blowup;
    # the invariant we need is rescaled ~ no OOR beyond the two edge slots.
    assert oor <= 2
    assert oor <= oor_raw


def test_empty_leaf_fallback_to_root():
    # keys concentrated in one corner -> most leaves empty
    keys = jnp.asarray(np.sort(np.random.default_rng(4).integers(0, 100, 50)).astype(np.uint32))
    p = rescale.fit_rescale(keys)
    scaled = rescale.rescale(p, keys)
    params = rmi.fit_rmi(scaled, jnp.ones_like(scaled), n_leaves=16)
    pred = rmi.predict(params, scaled)
    assert bool(jnp.all(jnp.isfinite(pred)))

"""Property tests for the shared top-k machinery (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: skip cleanly when absent
from hypothesis import given, settings, strategies as st

from repro.core.utils import dedup_topk, merge_topk, recall_at_k


@given(st.integers(0, 5000), st.integers(1, 40), st.integers(1, 12))
@settings(max_examples=40, deadline=None)
def test_dedup_topk_matches_bruteforce(seed, c, k):
    rng = np.random.default_rng(seed)
    ids = rng.integers(-1, 10, size=(3, c)).astype(np.int32)
    # equal ids must carry equal scores (they denote the same vector)
    base_scores = rng.normal(size=11).astype(np.float32)
    scores = np.where(ids >= 0, base_scores[np.maximum(ids, 0)], -np.inf)
    got_ids, got_scores = dedup_topk(jnp.asarray(ids), jnp.asarray(scores), k)
    got_ids = np.asarray(got_ids)
    got_scores = np.asarray(got_scores)
    for row in range(3):
        uniq = {i: s for i, s in zip(ids[row], scores[row]) if i >= 0}
        want = sorted(uniq.items(), key=lambda kv: -kv[1])[:k]
        got_valid = [(i, s) for i, s in zip(got_ids[row], got_scores[row]) if i >= 0]
        assert len(got_valid) == len(want)
        assert {i for i, _ in got_valid} == {i for i, _ in want}
        np.testing.assert_allclose(
            sorted([s for _, s in got_valid], reverse=True),
            [s for _, s in want],
            rtol=1e-6,
        )
        # no duplicates, scores descending over the valid prefix
        v = got_ids[row][got_ids[row] >= 0]
        assert len(set(v.tolist())) == len(v)
        fin = got_scores[row][np.isfinite(got_scores[row])]
        assert (np.diff(fin) <= 1e-9).all()


@given(st.integers(0, 1000), st.integers(1, 4), st.integers(1, 8))
@settings(max_examples=25, deadline=None)
def test_merge_topk_equals_global(seed, shards, k):
    rng = np.random.default_rng(seed)
    ids = rng.permutation(1000)[: shards * k].reshape(1, shards, k).astype(np.int32)
    scores = rng.normal(size=(1, shards, k)).astype(np.float32)
    # per-shard lists must be sorted descending (as produced by top_k)
    order = np.argsort(-scores, axis=-1)
    scores = np.take_along_axis(scores, order, -1)
    ids = np.take_along_axis(ids, order, -1)
    m_ids, m_scores = merge_topk(jnp.asarray(ids), jnp.asarray(scores), k)
    flat = sorted(
        zip(ids.reshape(-1), scores.reshape(-1)), key=lambda t: -t[1]
    )[:k]
    np.testing.assert_allclose(np.asarray(m_scores)[0], [s for _, s in flat], rtol=1e-6)


def test_recall_at_k_basics():
    pred = jnp.asarray([[1, 2, 3], [4, 5, -1]])
    true = jnp.asarray([[1, 9, 3], [4, 5, 6]])
    r = float(recall_at_k(pred, true))
    assert abs(r - (2 / 3 + 2 / 3) / 2) < 1e-6

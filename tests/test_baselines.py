"""Baseline ANN indexes: exactness of Flat, sanity of the approximate ones."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.baselines import (
    build_ivfpq,
    build_mplsh,
    build_pq,
    build_sklsh,
    flat_search,
    ivfpq_search,
    mplsh_search,
    pq_search,
    sklsh_search,
)
from repro.core.baselines.pq import _decode, _encode
from repro.core.utils import recall_at_k


def test_flat_is_exact(corpus):
    x, q, gt = corpus
    res = flat_search(x, q, k=10)
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(gt))
    # chunk size must not matter
    res2 = flat_search(x, q, k=10, chunk=1000)
    np.testing.assert_array_equal(np.asarray(res2.ids), np.asarray(gt))


def test_pq_reconstruction_improves_with_subspaces(corpus):
    x, _, _ = corpus
    errs = []
    for m in (2, 8):
        pq = build_pq(jax.random.PRNGKey(1), x, n_subspaces=m, bits=5, kmeans_iters=6)
        recon = _decode(pq.codebooks, pq.codes)
        errs.append(float(jnp.mean((recon - x) ** 2)))
    assert errs[1] < errs[0]


def test_pq_recall_reasonable(corpus):
    x, q, gt = corpus
    pq = build_pq(jax.random.PRNGKey(1), x, n_subspaces=8, bits=6, kmeans_iters=8)
    r = float(recall_at_k(pq_search(pq, q, k=10).ids, gt))
    assert r > 0.05  # quantized but far above random (10/4000)


def test_opq_and_pcapq_build(corpus):
    x, q, gt = corpus
    opq = build_pq(jax.random.PRNGKey(1), x, n_subspaces=8, bits=5, kmeans_iters=5, opq_iters=1)
    assert opq.rotation is not None
    r = float(recall_at_k(pq_search(opq, q, k=10).ids, gt))
    assert r > 0.05
    ppq = build_pq(jax.random.PRNGKey(1), x, n_subspaces=8, bits=5, kmeans_iters=5, pca_dim=32)
    assert ppq.rotation.shape == (64, 32)
    assert float(recall_at_k(pq_search(ppq, q, k=10).ids, gt)) > 0.05


def test_ivfpq_recall_improves_with_probes(corpus):
    x, q, gt = corpus
    ivf = build_ivfpq(jax.random.PRNGKey(2), x, n_subspaces=8, bits=6, kmeans_iters=8)
    r2 = float(recall_at_k(ivfpq_search(ivf, q, k=10, n_probe=2).ids, gt))
    r16 = float(recall_at_k(ivfpq_search(ivf, q, k=10, n_probe=16).ids, gt))
    assert r16 >= r2
    assert r16 > 0.15


def test_sklsh_recall(corpus):
    x, q, gt = corpus
    sk = build_sklsh(jax.random.PRNGKey(3), x, n_arrays=16)
    r = float(recall_at_k(sklsh_search(sk, x, q, k=10, n_candidates=100).ids, gt))
    assert r > 0.5


def test_mplsh_recall_and_probing(corpus):
    x, q, gt = corpus
    mp = build_mplsh(jax.random.PRNGKey(4), x, n_tables=16)
    r1 = float(recall_at_k(mplsh_search(mp, x, q, k=10, n_probes=1).ids, gt))
    r8 = float(recall_at_k(mplsh_search(mp, x, q, k=10, n_probes=8).ids, gt))
    assert r8 >= r1
    assert r8 > 0.6

"""Multi-replica serving fabric (DESIGN.md §Replica fabric): health state
machine, router dispatch and bit-identity, hedging, failover, replica
kill, the wrong-generation guard, and zero-downtime rolling updates."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import faults
from repro.core import lider, update
from repro.core.utils import l2_normalize
from repro.serving import (
    DEAD,
    HEALTHY,
    RECOVERING,
    SUSPECT,
    HealthPolicy,
    QueryResult,
    QueryRouter,
    ReplicaSet,
    RetrievalEngine,
    RouterConfig,
    Shed,
    make_backend,
)

N, DIM, K, BATCH = 400, 16, 5, 8
CFG = lider.LiderConfig(
    n_clusters=8, n_probe=4, n_arrays=4, n_leaves=4, kmeans_iters=5,
    storage_dtype="int8", rescore_tier="host",
)


@pytest.fixture(scope="module")
def data():
    x = l2_normalize(jax.random.normal(jax.random.PRNGKey(0), (N + 32, DIM)))
    base, held = np.asarray(x[:N]), np.asarray(x[N:])
    q = np.asarray(l2_normalize(x[:N][:32] + 0.02), np.float32)
    return base, held, q


def build_engine(data, fault_plan=None):
    base, _, _ = data
    # Each replica gets its OWN params build (deterministic, so replicas
    # are bit-identical) — host-tier stores mutate in place on update and
    # must never be shared across replicas.
    eng = RetrievalEngine(
        make_backend("lider", None, updatable=True, n_probe=4),
        batch_size=BATCH, k=K, dim=DIM,
        params=lider.build_lider(
            jax.random.PRNGKey(1), jnp.asarray(base), CFG
        ),
        fault_plan=fault_plan,
    )
    eng.warmup()
    return eng


def run(router, queries, *, max_dispatches=None):
    rids = [router.submit(v) for v in queries]
    while router.pending_requests:
        router.drain(max_dispatches=max_dispatches)
    return [router.result(r) for r in rids]


def serve_single(engine, queries):
    out = []
    for v in queries:
        rid = engine.submit(v)
        engine.drain()
        out.append(engine.result(rid))
    return out


# ---------------------------------------------------------------------------
# Health state machine (no engines needed)
# ---------------------------------------------------------------------------
class _FakeEngine:
    generation = 0


def test_health_state_machine_transitions():
    pol = HealthPolicy(
        dead_after=2, recover_successes=2, reprobe_backoff_s=0.01
    )
    rs = ReplicaSet([_FakeEngine(), _FakeEngine()], policy=pol)
    r = rs.get("r0")
    assert r.state == HEALTHY

    rs.record_failure(r, now=0.0)
    assert r.state == SUSPECT
    rs.record_success(r, 0.01)
    assert r.state == HEALTHY  # one success clears suspicion

    rs.record_failure(r, now=0.0)
    rs.record_failure(r, now=0.0)
    assert r.state == DEAD and not r.serveable()
    # Seeded jitter in [1, 2) over the base backoff window.
    assert 0.01 <= r.reprobe_at < 0.02

    rs.tick(now=r.reprobe_at - 1e-4)
    assert r.state == DEAD  # backoff window not over yet
    rs.tick(now=r.reprobe_at + 1e-4)
    assert r.state == RECOVERING  # reprobe heartbeat succeeded (no plan)
    rs.record_success(r, 0.01)
    assert r.state == HEALTHY  # recover_successes reached
    assert r.backoff_s is None  # backoff reset on full recovery


def test_failed_reprobe_doubles_backoff_deterministically():
    pol = HealthPolicy(dead_after=1, reprobe_backoff_s=0.01)
    plan = faults.FaultPlan(
        [faults.FaultSpec("replica_heartbeat", mode="error", times=(0,))],
        seed=0,
    )

    def windows(seed):
        rs = ReplicaSet(
            [_FakeEngine()],
            policy=HealthPolicy(
                dead_after=1, reprobe_backoff_s=0.01, seed=seed
            ),
            fault_plan=faults.FaultPlan(plan.to_json()["faults"], seed=0),
        )
        r = rs.get("r0")
        rs.record_failure(r, now=0.0)
        first = r.reprobe_at
        rs.tick(now=first + 1e-4)  # reprobe heartbeat: injected miss
        assert r.state == DEAD
        return first, r.reprobe_at - (first + 1e-4), r.backoff_s

    f1, w1, b1 = windows(seed=3)
    assert b1 == pytest.approx(0.02)  # doubled after the failed reprobe
    assert 0.02 <= w1 < 0.04
    f2, w2, b2 = windows(seed=3)
    assert (f1, w1) == (f2, w2)  # per-replica seeded jitter replays
    f3, _, _ = windows(seed=4)
    assert f3 != f1


def test_rollskip_stale_replica_never_serves():
    rs = ReplicaSet([_FakeEngine(), _FakeEngine()])
    r = rs.get("r1")
    r.stale = True
    assert not r.serveable()
    assert rs.pick(exclude=["r0"]) is None


# ---------------------------------------------------------------------------
# Fault-plan plumbing for the replica sites
# ---------------------------------------------------------------------------
def test_spec_targets_and_site_counts():
    spec = faults.FaultSpec(
        "replica_dispatch", mode="straggle", payload={"replica": "r1"}
    )
    assert faults.spec_targets(spec, "r1")
    assert not faults.spec_targets(spec, "r0")
    assert faults.spec_targets(
        faults.FaultSpec("replica_dispatch", mode="straggle"), "r0"
    )
    assert not faults.spec_targets(None, "r0")

    plan = faults.FaultPlan(
        [faults.FaultSpec("replica_kill", mode="kill_replica", times=(0,))]
    )
    counts = plan.site_counts()
    assert set(faults.SITES) <= set(counts)
    assert all(v == 0 for v in counts.values())  # zero-filled pre-fire
    plan.fire(faults.REPLICA_KILL)
    assert plan.site_counts()[faults.REPLICA_KILL] == 1
    assert plan.site_counts()[faults.REPLICA_DISPATCH] == 0


# ---------------------------------------------------------------------------
# Router over real replicas
# ---------------------------------------------------------------------------
def test_router_matches_single_engine_bit_for_bit(data):
    _, _, q = data
    router = QueryRouter([build_engine(data), build_engine(data)])
    res = run(router, q)
    router.close()
    single = build_engine(data)
    want = serve_single(single, q)
    assert all(isinstance(r, QueryResult) for r in res)
    for a, b in zip(res, want):
        np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
        np.testing.assert_array_equal(
            np.asarray(a.scores), np.asarray(b.scores)
        )
    # Both replicas took traffic, every answer is stamped with its server.
    assert {a.replica for a in res} == {"r0", "r1"}
    assert all(a.generation == 0 for a in res)
    assert router.stats.availability == 1.0


def test_targeted_dispatch_failure_fails_over(data):
    _, _, q = data
    plan = faults.FaultPlan(
        [
            faults.FaultSpec(
                "replica_dispatch", mode="fail", probability=1.0,
                count=3, payload={"replica": "r0"},
            )
        ],
        seed=1,
    )
    router = QueryRouter(
        [build_engine(data, plan), build_engine(data, plan)],
        fault_plan=plan,
    )
    res = run(router, q)
    router.close()
    assert all(isinstance(r, QueryResult) for r in res)  # nothing lost
    assert router.stats.n_failovers > 0
    assert router.stats.n_dispatch_failures >= 1
    r0 = router.replicas.get("r0")
    assert r0.n_failures >= 1
    assert r0.state in (SUSPECT, HEALTHY)  # recovered once faults ran out


def test_replica_kill_mid_trace_fails_over_and_stays_dead(data):
    _, _, q = data
    plan = faults.FaultPlan(
        [
            faults.FaultSpec(
                "replica_kill", mode="kill_replica", times=(2,),
                payload={"replica": "r1"},
            )
        ],
        seed=2,
    )
    router = QueryRouter(
        [build_engine(data, plan), build_engine(data, plan)],
        fault_plan=plan,
    )
    qs = np.concatenate([q, q * 0.99])
    res = run(router, qs, max_dispatches=1)  # many drain calls -> kill fires
    router.close()
    assert router.stats.n_replica_kills == 1
    r1 = router.replicas.get("r1")
    assert r1.killed and r1.state == DEAD and r1.reprobe_at is None
    assert all(isinstance(r, QueryResult) for r in res)  # zero lost queries
    # After the kill every answer came from the survivor.
    assert router.stats.availability == 1.0


def test_wrong_generation_guard_discards_and_fails_over(data):
    _, held, q = data
    router = QueryRouter([build_engine(data), build_engine(data)])
    r0 = router.replicas.get("r0")
    new_rows = jnp.asarray(held[:8])
    orig = r0.engine.execute_chunk
    raced = {"done": False}

    def racy_execute(chunk):
        # An update applied directly to the engine (outside RouterControl)
        # races this in-flight batch: the answer comes back stamped with
        # the new generation while the router dispatched against the old.
        if not raced["done"]:
            raced["done"] = True
            r0.engine.apply_updates(
                lambda p: update.upsert(p, new_rows)
            )
        return orig(chunk)

    r0.engine.execute_chunk = racy_execute
    res = run(router, q)
    router.close()
    assert router.stats.n_wrong_generation > 0  # guard tripped...
    assert all(isinstance(r, QueryResult) for r in res)  # ...yet all served
    # No delivered answer carries a generation other than its replica's.
    for a in res:
        assert a.generation == router.replicas.get(a.replica).generation


def test_hedging_rescues_straggler(data):
    _, _, q = data
    plan = faults.FaultPlan(
        [
            faults.FaultSpec(
                "replica_dispatch", mode="straggle", probability=1.0,
                delay_s=0.25, payload={"replica": "r0"},
            )
        ],
        seed=5,
    )
    cfg = RouterConfig(hedge_quantile=0.5, hedge_min_samples=4)
    router = QueryRouter(
        [build_engine(data, plan), build_engine(data, plan)],
        config=cfg, fault_plan=plan,
    )
    qs = np.concatenate([q, q * 0.99, q * 1.01])
    res = run(router, qs)
    router.close()
    assert all(isinstance(r, QueryResult) for r in res)
    assert router.stats.n_hedges >= 1
    assert router.stats.n_hedge_wins >= 1  # the hedge beat a 0.25s straggle
    # Hedge-rescued answers did not pay the full straggle delay: with the
    # injected 0.25s sleep on r0 every hedged batch still answered fast.
    assert router.stats.n_wrong_generation == 0


def test_rolling_update_zero_downtime_and_bit_identity(data):
    _, held, q = data
    router = QueryRouter(
        [build_engine(data), build_engine(data), build_engine(data)]
    )
    _ = run(router, q)  # pre-roll traffic
    new_rows = np.asarray(held[:16], np.float32)

    def up(params):
        return update.upsert(params, jnp.asarray(new_rows))

    # Non-blocking roll: traffic keeps flowing while replicas update one
    # at a time behind the mask.
    router.control.apply_updates(up, block=False)
    mixed = run(router, np.concatenate([q, q * 0.99]))
    router.control.wait(timeout=60.0)
    assert router.stats.n_rolls_completed == 1
    assert router.stats.n_roll_replicas_updated == 3
    assert router.generation_window() == (1, 1)  # window closed
    # Zero downtime, zero losses, zero wrong-generation answers — every
    # mixed-window answer matches its serving replica's generation stamp.
    assert all(isinstance(r, QueryResult) for r in mixed)
    assert router.stats.n_wrong_generation == 0

    res = run(router, q)
    router.close()
    single = build_engine(data)
    single.apply_updates(up)
    want = serve_single(single, q)
    for a, b in zip(res, want):
        assert a.generation == 1
        np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
        np.testing.assert_array_equal(
            np.asarray(a.scores), np.asarray(b.scores)
        )


def test_rolling_update_skips_killed_replica_as_stale(data):
    _, held, q = data
    router = QueryRouter(
        [build_engine(data), build_engine(data), build_engine(data)]
    )
    _ = run(router, q)
    router.replicas.kill("r1")
    new_rows = jnp.asarray(held[:8])
    router.control.apply_updates(lambda p: update.upsert(p, new_rows))
    assert router.stats.n_roll_replicas_updated == 2
    assert router.stats.n_roll_replicas_skipped == 1
    r1 = router.replicas.get("r1")
    assert r1.stale and not r1.serveable()  # never rejoins at the old gen
    assert router.generation_window() == (1, 1)
    res = run(router, q)
    router.close()
    assert all(a.generation == 1 for a in res)
    assert {a.replica for a in res} <= {"r0", "r2"}


def test_rolling_update_retries_failed_attempt_once(data):
    # A transiently failing update_fn must be retried — not skipped as
    # stale. Regression: the roll's own `updating` mask used to read as
    # ill-health on the retry pass, silently skipping the replica.
    _, held, q = data
    router = QueryRouter([build_engine(data), build_engine(data)])
    _ = run(router, q)
    new_rows = jnp.asarray(held[:8])
    calls = {"n": 0}

    def flaky_up(params):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("transient update failure")
        return update.upsert(params, new_rows)

    router.control.apply_updates(flaky_up)
    assert router.stats.n_roll_update_failures == 1
    assert router.stats.n_roll_replicas_updated == 2
    assert router.stats.n_roll_replicas_skipped == 0
    assert router.generation_window() == (1, 1)
    assert all(not r.stale and r.serveable() for r in router.replicas)
    res = run(router, q)
    router.close()
    assert all(a.generation == 1 for a in res)


def test_no_serveable_replicas_sheds_structurally(data):
    _, _, q = data
    router = QueryRouter([build_engine(data)])
    router.replicas.kill("r0")
    res = run(router, q[:BATCH])
    router.close()
    assert all(isinstance(r, Shed) for r in res)
    assert {r.reason for r in res} == {"no_replica"}
    assert router.stats.availability < 1.0

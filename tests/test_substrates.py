"""Substrate tests: optimizer, train loop, checkpointing, data pipeline,
fault tolerance."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import pipeline as pipe_lib
from repro.data import synthetic
from repro.models import transformer as tfm
from repro.training import checkpoint as ckpt
from repro.training import fault_tolerance as ft
from repro.training import optimizer as opt_lib
from repro.training import train_loop

CFG = tfm.LMConfig(
    name="tiny", n_layers=2, d_model=32, n_heads=2, n_kv_heads=1, d_ff=64,
    vocab=64, dtype=jnp.float32,
)


def _setup():
    params = tfm.init(jax.random.PRNGKey(0), CFG)
    ocfg = opt_lib.OptimizerConfig(peak_lr=1e-2, warmup_steps=2, decay_steps=40)
    return params, ocfg, opt_lib.init_state(params)


def test_training_reduces_loss():
    params, ocfg, state = _setup()
    step = train_loop.make_train_step(
        lambda p, b: tfm.train_loss(p, CFG, b), ocfg, grad_accum=1
    )
    pipe = pipe_lib.DataPipeline(
        lambda s: synthetic.lm_batch(0, s % 4, batch=4, seq=16, vocab=64), prefetch=0
    )
    _, _, hist = train_loop.run(step, params, state, pipe, n_steps=25, log_every=1)
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.1


def test_grad_accum_matches_full_batch():
    params, ocfg, state = _setup()
    batch = synthetic.lm_batch(0, 0, batch=8, seq=16, vocab=64)
    s1 = train_loop.make_train_step(lambda p, b: tfm.train_loss(p, CFG, b), ocfg, grad_accum=1)
    s2 = train_loop.make_train_step(lambda p, b: tfm.train_loss(p, CFG, b), ocfg, grad_accum=4)
    p1, _, m1 = jax.jit(s1)(params, state, batch)
    p2, _, m2 = jax.jit(s2)(params, state, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 2e-3
    diffs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), p1, p2)
    assert max(jax.tree.leaves(diffs)) < 2e-3


def test_schedule_shape():
    ocfg = opt_lib.OptimizerConfig(peak_lr=1.0, warmup_steps=10, decay_steps=100, min_lr_ratio=0.1)
    lrs = [float(opt_lib.schedule(ocfg, jnp.int32(s))) for s in (0, 5, 10, 50, 100, 1000)]
    assert lrs[0] == 0.0 and abs(lrs[2] - 1.0) < 1e-6
    assert lrs[3] < 1.0 and abs(lrs[4] - 0.1) < 1e-6 and abs(lrs[5] - 0.1) < 1e-6


def test_gradient_compression_close_to_exact():
    params, _, state = _setup()
    batch = synthetic.lm_batch(0, 0, batch=4, seq=16, vocab=64)
    loss, grads = jax.value_and_grad(lambda p: tfm.train_loss(p, CFG, batch))(params)
    exact = opt_lib.apply_updates(params, grads, state, opt_lib.OptimizerConfig())[0]
    comp = opt_lib.apply_updates(
        params, grads, state, opt_lib.OptimizerConfig(compress_grads=True)
    )[0]
    rel = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b)) / (jnp.max(jnp.abs(a)) + 1e-9)),
        exact,
        comp,
    )
    assert max(jax.tree.leaves(rel)) < 0.1


def test_checkpoint_roundtrip_and_gc():
    params, _, state = _setup()
    with tempfile.TemporaryDirectory() as d:
        mgr = ckpt.CheckpointManager(d, keep=2)
        for s in (5, 10, 15):
            mgr.save(s, {"params": params, "opt": state})
        assert mgr.latest_step() == 15
        # keep=2 -> step 5 gone
        assert not os.path.exists(os.path.join(d, "step_00000005"))
        step, restored = mgr.restore_latest({"params": params, "opt": state})
        assert step == 15
        for a, b in zip(jax.tree.leaves(restored["params"]), jax.tree.leaves(params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
            assert a.dtype == b.dtype


def test_checkpoint_restore_rejects_wrong_structure():
    params, _, _ = _setup()
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 1, {"a": jnp.zeros((2,))})
        with pytest.raises(AssertionError):
            ckpt.restore(d, 1, {"a": jnp.zeros((2,)), "b": jnp.zeros((2,))})


def test_pipeline_determinism_and_replay():
    make = lambda s: synthetic.lm_batch(7, s, batch=2, seq=8, vocab=32)
    p1 = pipe_lib.DataPipeline(make, prefetch=2)
    first = [next(p1) for _ in range(5)]
    p1.close()
    # replay from step 3 reproduces batches exactly
    p2 = pipe_lib.DataPipeline(make, start_step=3, prefetch=0)
    replay = next(p2)
    np.testing.assert_array_equal(
        np.asarray(first[3]["tokens"]), np.asarray(replay["tokens"])
    )


def test_preemption_restart_is_exact():
    calls = {"n": 0}

    def make_state():
        return {"acc": jnp.zeros(())}

    def step_fn(st, i):
        calls["n"] += 1
        if calls["n"] == 6:
            raise ft.Preemption()
        return {"acc": st["acc"] + i * i}

    with tempfile.TemporaryDirectory() as d:
        mgr = ckpt.CheckpointManager(d)
        final, restarts = ft.run_with_restarts(
            make_state, step_fn, n_steps=9, manager=mgr, checkpoint_every=2
        )
    assert restarts == 1
    assert float(final["acc"]) == sum(i * i for i in range(9))

import jax
import jax.numpy as jnp
import pytest

from repro.core.utils import l2_normalize

# NOTE: no XLA_FLAGS here — unit tests must see the real single CPU device.
# Multi-device tests (tests/test_distributed.py) spawn subprocesses that set
# xla_force_host_platform_device_count themselves.


@pytest.fixture(scope="session")
def corpus():
    """Clustered unit-norm corpus (4000 x 64) + queries + exact top-10."""
    rng = jax.random.PRNGKey(0)
    kc, kx, kq, kb = jax.random.split(rng, 4)
    centers = jax.random.normal(kc, (32, 64))
    assign = jax.random.randint(kx, (4000,), 0, 32)
    x = l2_normalize(centers[assign] + 0.3 * jax.random.normal(kq, (4000, 64)))
    q = l2_normalize(x[:64] + 0.05 * jax.random.normal(kb, (64, 64)))
    gt = jax.lax.top_k(q @ x.T, 10)[1]
    return x, q, gt
